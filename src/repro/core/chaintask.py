"""Host-side four-phase Chainwrite orchestration (paper §III-A/B, Fig. 4).

Inside a compiled XLA step the four-phase handshake is static (see
DESIGN.md §2), but *between* steps the serving/training runtime really
does orchestrate dynamic P2MP movement (weight refresh, KV-block
multicast, elastic re-layout). This module is that application layer:

* :class:`ChainConfig` — the cfg packet of Fig. 4(c): chain linkage
  (prev/next node), transfer geometry for the backend (AXI size field),
  and the ND-affine access pattern for the DSE (field F).
* :class:`ChainTask` — a P2MP task driven through the four phases
  CFG_DISPATCH → GRANT_BACKPROP → DATA → FINISH_BACKPROP, with a
  per-phase cycle ledger from :mod:`.simulator` so runtime decisions
  (chain vs unicast, scheduler choice) can be made from predicted cost.
* :class:`MultiChainTask` — the multi-chain extension: partitions the
  destination set into K link-disjoint-preferring sub-chains
  (``scheduling.partition_schedule``) and drives one :class:`ChainTask`
  per sub-chain, with a merged per-phase ledger whose ``total`` is the
  concurrent critical path (``simulator.multi_chain_latency``), plus a
  per-sub-chain ledger list (``per_chain_ledgers``). Failures
  injected via :meth:`MultiChainTask.inject_failure` accumulate a
  failure *set* driving the recovery path: every affected sub-chain
  is re-formed (``scheduling.reform_chain``), the survivors still
  receive the payload, and the recovery cycles (one
  ``core.program.plan_recovery`` schedule priced by
  ``simulator.chain_recovery_latency``) are charged *only* to the
  affected sub-chains' ledgers — every unaffected sub-chain's ledger
  is CC-identical to the failure-free run.

The DATA phase executes a real copy through a pluggable ``transport``
(by default an in-process store-and-forward through per-node buffers —
each hop duplicates the stream to the local memory and the next hop,
mirroring the Torrent data switch ①–④ port semantics).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Sequence

import numpy as np

from . import simulator
from .scheduling import SCHEDULERS, partition_schedule
from .topology import MeshTopology


class Phase(enum.Enum):
    IDLE = "idle"
    CFG_DISPATCH = "cfg_dispatch"
    GRANT_BACKPROP = "grant_backprop"
    DATA = "data"
    FINISH_BACKPROP = "finish_backprop"
    DONE = "done"


@dataclasses.dataclass(frozen=True)
class AffinePattern:
    """ND-affine access pattern (cfg field F — the DSE program).

    Reads ``prod(bounds)`` elements at ``base + sum_i idx_i*strides_i``.
    """

    base: int
    bounds: tuple[int, ...]
    strides: tuple[int, ...]

    def indices(self) -> np.ndarray:
        idx = np.zeros((), dtype=np.int64)
        for b, s in zip(self.bounds, self.strides):
            idx = idx[..., None] + np.arange(b, dtype=np.int64) * s
        return (self.base + idx).reshape(-1)


@dataclasses.dataclass(frozen=True)
class ChainConfig:
    """One cfg frame body (Fig. 4(c) fields A–F)."""

    node: int  # this Torrent's node id
    prev_node: int | None  # field A/B: upstream link (None = initiator)
    next_node: int | None  # field C/D: downstream link (None = tail)
    size_bytes: int  # field E: AXI transfer size
    pattern: AffinePattern  # field F: DSE access pattern


Transport = Callable[[int, int, np.ndarray], None]


class ChainTask:
    """A single P2MP Chainwrite task, orchestrated in four phases."""

    def __init__(
        self,
        topo: MeshTopology,
        source: int,
        destinations: Sequence[int],
        payload: np.ndarray,
        *,
        scheduler: str = "greedy",
        order: Sequence[int] | None = None,
        pattern: AffinePattern | None = None,
        sim_params: simulator.SimParams = simulator.DEFAULT_PARAMS,
    ) -> None:
        if len(set(destinations)) != len(destinations):
            raise ValueError("duplicate destinations")
        if source in destinations:
            raise ValueError("source cannot be a destination")
        self.topo = topo
        self.source = source
        self.payload = np.ascontiguousarray(payload)
        if order is not None:
            # Caller supplies a pre-computed traversal (e.g. one
            # sub-chain of a MultiChainTask partition).
            if sorted(order) != sorted(destinations):
                raise ValueError("order must permute the destinations")
            self.order = [int(d) for d in order]
        else:
            self.order = SCHEDULERS[scheduler](topo, list(destinations), source)
        self.scheduler = scheduler
        self.sim_params = sim_params
        self.pattern = pattern or AffinePattern(
            base=0, bounds=(self.payload.size,), strides=(1,)
        )
        self.phase = Phase.IDLE
        self.grants: set[int] = set()
        self.finishes: set[int] = set()
        self.node_buffers: dict[int, np.ndarray] = {}
        self.cycle_ledger: dict[str, int] = {}

    # -- cfg packets (Fig. 4c) ----------------------------------------
    def configs(self) -> list[ChainConfig]:
        chain = [self.source] + list(self.order)
        cfgs = []
        for i, node in enumerate(chain):
            cfgs.append(
                ChainConfig(
                    node=node,
                    prev_node=chain[i - 1] if i > 0 else None,
                    next_node=chain[i + 1] if i + 1 < len(chain) else None,
                    size_bytes=self.payload.nbytes,
                    pattern=self.pattern,
                )
            )
        return cfgs

    # -- four-phase execution ------------------------------------------
    def run(self, transport: Transport | None = None) -> dict[int, np.ndarray]:
        """Drive all four phases; returns the per-destination buffers."""
        p = self.sim_params
        chain = [self.source] + list(self.order)
        n = len(self.order)

        # Phase 1 — cfg dispatch (initiator -> all members, parallel).
        self.phase = Phase.CFG_DISPATCH
        far = max(self.topo.distance(self.source, d) for d in self.order)
        self.cycle_ledger["cfg"] = (
            p.dma_setup_cc + n * p.cfg_inject_cc + far * p.router_cc + p.cfg_proc_cc
        )

        # Phase 2 — grant backward propagation (tail -> head). A node
        # forwards the grant only once it is ready (models Fig. 4(b)).
        self.phase = Phase.GRANT_BACKPROP
        for node in reversed(chain[1:]):
            self.grants.add(node)
        hops = sum(
            self.topo.distance(a, b) for a, b in zip(chain, chain[1:])
        )
        self.cycle_ledger["grant"] = hops * p.router_cc + n * p.grant_fwd_cc

        # Phase 3 — data: store-and-forward through every member.
        self.phase = Phase.DATA
        flat = self.payload.reshape(-1)
        gathered = flat[self.pattern.indices() % flat.size]
        for prev, node in zip(chain, chain[1:]):
            if transport is not None:
                transport(prev, node, gathered)
            self.node_buffers[node] = gathered.copy()
        self.cycle_ledger["data"] = (
            hops * p.router_cc
            + n * p.sf_fill_cc
            + simulator._ceil_div(gathered.nbytes, simulator._effective_bw(p, 1))
        )

        # Phase 4 — finish backward propagation (tail -> head).
        self.phase = Phase.FINISH_BACKPROP
        for node in reversed(chain[1:]):
            self.finishes.add(node)
        self.cycle_ledger["finish"] = hops * p.router_cc + n * p.finish_fwd_cc

        self.phase = Phase.DONE
        self.cycle_ledger["total"] = sum(
            self.cycle_ledger[k] for k in ("cfg", "grant", "data", "finish")
        )
        return self.node_buffers

    # -- cost predictions (runtime policy) ------------------------------
    def predicted_cycles(self) -> int:
        return simulator.chainwrite_latency(
            self.topo, self.source, self.order, self.payload.nbytes, self.sim_params
        )

    def unicast_cycles(self) -> int:
        return simulator.unicast_latency(
            self.topo, self.source, self.order, self.payload.nbytes, self.sim_params
        )

    def speedup_vs_unicast(self) -> float:
        return self.unicast_cycles() / max(1, self.predicted_cycles())


class MultiChainTask:
    """K concurrent Chainwrite sub-chains from one initiator.

    The destination set is split by ``scheduling.partition_schedule``
    (``num_chains=None`` -> K chosen by the calibrated cycle model via
    ``simulator.choose_num_chains``); one :class:`ChainTask` drives each
    sub-chain through its four phases. The merged ``cycle_ledger``
    models the shared cfg-inject port: per-phase entries are the
    critical (max-over-chains) values with cfg serialization applied,
    and ``total`` is ``simulator.multi_chain_latency`` — the concurrent
    critical path, which is at most the sum of the per-phase maxima and
    exactly the single-chain ledger when K=1.
    """

    def __init__(
        self,
        topo: MeshTopology,
        source: int,
        destinations: Sequence[int],
        payload: np.ndarray,
        *,
        num_chains: int | None = None,
        chains: Sequence[Sequence[int]] | None = None,
        scheduler: str = "tsp",
        pattern: AffinePattern | None = None,
        sim_params: simulator.SimParams = simulator.DEFAULT_PARAMS,
    ) -> None:
        if len(set(destinations)) != len(destinations):
            raise ValueError("duplicate destinations")
        if source in destinations:
            raise ValueError("source cannot be a destination")
        self.topo = topo
        self.source = source
        self.payload = np.ascontiguousarray(payload)
        self.sim_params = sim_params
        if chains is not None:
            # Caller supplies the partition (e.g. a MultiChainPlan's
            # possibly re-formed schedule); must cover the destinations.
            chains = [[int(d) for d in c] for c in chains if len(c)]
            flat = [d for c in chains for d in c]
            if sorted(flat) != sorted(int(d) for d in destinations):
                raise ValueError("chains must partition the destinations")
            self.chains = chains
            self.num_chains = len(chains)
        elif num_chains is None:
            self.num_chains, self.chains = simulator.choose_num_chains(
                topo, source, list(destinations), self.payload.nbytes,
                scheduler=scheduler, p=sim_params,
            )
        else:
            self.chains = partition_schedule(
                topo, list(destinations), source,
                num_chains=num_chains, scheduler=scheduler,
            )
            self.num_chains = len(self.chains)
        self.scheduler = scheduler
        self.pattern = pattern
        self.tasks = [
            ChainTask(
                topo, source, list(chain), self.payload,
                order=chain, pattern=pattern, sim_params=sim_params,
            )
            for chain in self.chains
        ]
        self.phase = Phase.IDLE
        self.failed_nodes: list[int] = []
        self.reformed_chains: list[list[int]] | None = None
        self.node_buffers: dict[int, np.ndarray] = {}
        self.cycle_ledger: dict[str, int] = {}
        self.per_chain_ledgers: list[dict[str, int]] = []

    @property
    def failed_node(self) -> int | None:
        """The sole injected failure (pre-failure-set compatibility).

        ``None`` before any injection; raises when several failures
        have accumulated — use :attr:`failed_nodes` then.
        """
        if not self.failed_nodes:
            return None
        if len(self.failed_nodes) > 1:
            raise RuntimeError(
                f"multiple failures injected {self.failed_nodes}; "
                "use failed_nodes"
            )
        return self.failed_nodes[0]

    def configs(self) -> list[ChainConfig]:
        """All chains' cfg frames in cfg-inject (serialization) order."""
        return [cfg for task in self.tasks for cfg in task.configs()]

    # -- failure injection (fault-tolerance hook) ----------------------
    def inject_failure(self, node: int) -> None:
        """Mark chain member ``node`` as dead before :meth:`run`.

        May be called several times to accumulate a *set* of
        concurrently dead members (the failure set the run recovers
        from). The run then takes the recovery path: each affected
        sub-chain is re-formed (``scheduling.reform_chain``), the
        payload still reaches every survivor, and the recovery cycles
        are charged only to the affected sub-chains' ledgers.

        Injecting the same node twice, or a node that is no longer (or
        never was) a chain member — e.g. one already spliced out of a
        re-formed partition the task was built with — raises.
        """
        if self.phase is not Phase.IDLE:
            raise RuntimeError("failure must be injected before run()")
        node = int(node)
        if node in self.failed_nodes:
            raise ValueError(f"node {node} already injected as failed")
        if not any(node in chain for chain in self.chains):
            raise ValueError(f"node {node} is not a chain member")
        self.failed_nodes.append(node)

    def run(self, transport: Transport | None = None) -> dict[int, np.ndarray]:
        """Drive every sub-chain; returns the merged destination buffers.

        With injected failures every affected sub-chain is re-formed
        and re-driven so every *surviving* destination still receives
        the payload; the failed nodes get no buffer.
        """
        self.phase = Phase.CFG_DISPATCH
        recoveries: list[dict[str, object]] = []
        if not self.failed_nodes:
            detail = simulator.multi_chain_latency(
                self.topo, self.source, self.chains, self.payload.nbytes,
                self.sim_params, detail=True,
            )
            per_phase = detail["per_phase"]
            total = detail["total"]
            for task in self.tasks:
                self.node_buffers.update(task.run(transport))
        else:
            rec_detail = simulator.chain_recovery_latency(
                self.topo, self.source, self.chains, set(self.failed_nodes),
                self.payload.nbytes, self.sim_params,
                scheduler=self.scheduler, detail=True,
            )
            recoveries = rec_detail["recoveries"]
            per_phase = rec_detail["per_phase"]  # failure-free split
            total = rec_detail["total"]  # already includes recovery
            affected = {r["chain"]: r for r in recoveries}
            for i, task in enumerate(self.tasks):
                if i not in affected:
                    self.node_buffers.update(task.run(transport))
            self.reformed_chains = [
                list(affected[i]["reformed"]) if i in affected else list(c)
                for i, c in enumerate(self.chains)
            ]
            for rec in recoveries:
                reformed = list(rec["reformed"])
                if reformed:
                    degraded = ChainTask(
                        self.topo, self.source, reformed, self.payload,
                        order=reformed, pattern=self.pattern,
                        sim_params=self.sim_params,
                    )
                    self.node_buffers.update(degraded.run(transport))
        self.phase = Phase.DONE

        # Per-sub-chain ledgers: cfg includes the shared-port stagger;
        # recovery cycles land only on the failed members' chains.
        self.per_chain_ledgers = [
            {
                "cfg": c, "grant": g, "data": d, "finish": f,
                "recovery": 0, "total": c + g + d + f,
            }
            for (c, g, d, f) in per_phase
        ]
        for rec in recoveries:
            lg = self.per_chain_ledgers[rec["chain"]]
            lg["recovery"] = rec["recovery_cc"]
            lg["total"] += rec["recovery_cc"]

        # Merged ledger: the concurrent phases take the max over
        # chains; total is the true critical path.
        phases = per_phase or [(0, 0, 0, 0)]  # empty dest set
        self.cycle_ledger = {
            "cfg": max(ph[0] for ph in phases),
            "grant": max(ph[1] for ph in phases),
            "data": max(ph[2] for ph in phases),
            "finish": max(ph[3] for ph in phases),
            "total": total,
        }
        if recoveries:
            # concurrent per-chain recoveries: the critical-path charge
            self.cycle_ledger["recovery"] = max(
                r["recovery_cc"] for r in recoveries
            )
        return self.node_buffers

    # -- cost predictions (runtime policy) ------------------------------
    def predicted_cycles(self) -> int:
        return simulator.multi_chain_latency(
            self.topo, self.source, self.chains, self.payload.nbytes,
            self.sim_params,
        )

    def single_chain_cycles(self, scheduler: str = "tsp") -> int:
        order = SCHEDULERS[scheduler](
            self.topo, [d for c in self.chains for d in c], self.source
        )
        return simulator.chainwrite_latency(
            self.topo, self.source, order, self.payload.nbytes, self.sim_params
        )

    def speedup_vs_single_chain(self) -> float:
        return self.single_chain_cycles() / max(1, self.predicted_cycles())

    def unicast_cycles(self) -> int:
        return simulator.unicast_latency(
            self.topo, self.source, [d for c in self.chains for d in c],
            self.payload.nbytes, self.sim_params,
        )

    def speedup_vs_unicast(self) -> float:
        return self.unicast_cycles() / max(1, self.predicted_cycles())
