"""Deterministic synthetic LM data pipeline with background prefetch.

Two sources:

* :class:`MarkovSource` — a fixed random k-ary Markov chain over the
  vocabulary. Entropy ≈ log(branch) nats/token, so a model that learns
  the chain drives CE from log(vocab) down toward log(branch) — this is
  what makes "train a ~100M model and watch the loss fall" meaningful
  with no external datasets.
* :class:`UniformSource` — i.i.d. uniform tokens (throughput testing).

Batches are generated per *step index* with a counter-based generator
(numpy Philox), so any host can regenerate any step independently —
restart/elastic-rescale replays the exact stream with zero coordination,
and each host slices only its addressable rows (host-sharded loading).

:class:`Prefetcher` runs the source on a background thread with a
bounded queue and optionally device_puts onto a NamedSharding
(double-buffered H2D).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np


class MarkovSource:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 branch: int = 4, seed: int = 0):
        self.vocab, self.seq_len, self.global_batch = vocab, seq_len, global_batch
        self.branch = branch
        self.seed = seed
        rng = np.random.Generator(np.random.Philox(key=seed))
        # fixed transition table: token -> `branch` possible successors
        self.table = rng.integers(0, vocab, size=(vocab, branch), dtype=np.int32)

    def batch(self, step: int, *, host_slice: slice = slice(None)) -> dict:
        rng = np.random.Generator(np.random.Philox(key=self.seed + 1, counter=step))
        B, S = self.global_batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=B)
        choices = rng.integers(0, self.branch, size=(B, S))
        for t in range(S):
            toks[:, t + 1] = self.table[toks[:, t], choices[:, t]]
        return {
            "tokens": toks[host_slice, :-1],
            "labels": toks[host_slice, 1:],
        }


class UniformSource:
    def __init__(self, vocab: int, seq_len: int, global_batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.global_batch = vocab, seq_len, global_batch
        self.seed = seed

    def batch(self, step: int, *, host_slice: slice = slice(None)) -> dict:
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=step))
        toks = rng.integers(
            0, self.vocab, size=(self.global_batch, self.seq_len + 1), dtype=np.int32
        )
        return {"tokens": toks[host_slice, :-1], "labels": toks[host_slice, 1:]}


class Prefetcher:
    """Background-thread prefetch (+ optional device placement)."""

    def __init__(
        self,
        source,
        start_step: int = 0,
        depth: int = 2,
        place: Callable[[dict], dict] | None = None,
    ):
        self._source = source
        self._place = place
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch(step)
            if self._place is not None:
                batch = self._place(batch)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_device_placer(mesh, spec) -> Callable[[dict], dict]:
    """device_put each array with NamedSharding(mesh, spec)."""
    from jax.sharding import NamedSharding

    sharding = NamedSharding(mesh, spec)

    def place(batch: dict) -> dict:
        return {k: jax.device_put(v, sharding) for k, v in batch.items()}

    return place
