from .pipeline import MarkovSource, Prefetcher, UniformSource, make_device_placer
