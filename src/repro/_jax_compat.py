"""Backfill newer-jax API names onto older jax installs (>= 0.4.37).

The repo is written against the current jax surface:

* ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., axis_names=...,
  check_vma=...)``
* ``jax.set_mesh(mesh)`` as a context manager
* ``jax.sharding.AxisType`` / ``Mesh.axis_types``
* ``jax.sharding.get_abstract_mesh()``
* ``jax.make_mesh(..., axis_types=...)``

Older jax (e.g. the 0.4.x line baked into this container) spells these
``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)``,
has no axis types, and resolves bare ``PartitionSpec`` sharding
constraints through the legacy ``with mesh:`` resource-env context.
:func:`install` bridges the gap by installing thin adapters onto the
``jax`` modules; it is a no-op wherever the real name already exists,
so the same tree runs unmodified on current jax.

Called once from ``repro/__init__.py`` — importing any ``repro``
module guarantees the shims are in place.
"""

from __future__ import annotations

import contextlib
import enum
import functools
import inspect
import threading

import jax


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


class _TLS(threading.local):
    def __init__(self):
        self.mesh = None  # innermost jax.set_mesh() mesh
        self.manual_axes: tuple[frozenset, ...] = ()


_tls = _TLS()


class _CompatAbstractMesh:
    """Just enough of AbstractMesh for ``parallel.hints``: axis names
    plus per-axis types (Manual inside a compat shard_map region)."""

    def __init__(self, mesh, manual: frozenset):
        self._mesh = mesh
        self._manual = manual

    @property
    def empty(self) -> bool:
        return self._mesh is None or not self._mesh.axis_names

    @property
    def axis_names(self):
        return self._mesh.axis_names if self._mesh is not None else ()

    @property
    def axis_types(self):
        at = jax.sharding.AxisType
        return tuple(
            at.Manual if name in self._manual else at.Auto
            for name in self.axis_names
        )

    @property
    def shape(self):
        return self._mesh.shape if self._mesh is not None else {}


def _get_abstract_mesh():
    manual = frozenset().union(*_tls.manual_axes) if _tls.manual_axes else frozenset()
    return _CompatAbstractMesh(_tls.mesh, manual)


@contextlib.contextmanager
def _set_mesh(mesh):
    """Compat ``jax.set_mesh``: legacy resource-env mesh context (so bare
    ``PartitionSpec`` sharding constraints resolve) + visibility to
    :func:`_get_abstract_mesh`."""
    prev = _tls.mesh
    _tls.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _tls.mesh = prev


def _make_shard_map(legacy_shard_map):
    def shard_map(
        f=None,
        *,
        mesh,
        in_specs,
        out_specs,
        axis_names=None,
        check_vma=None,
        check_rep=None,
    ):
        if f is None:  # decorator form: jax.shard_map(mesh=...)(f)
            return functools.partial(
                shard_map,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                axis_names=axis_names,
                check_vma=check_vma,
                check_rep=check_rep,
            )
        all_axes = frozenset(mesh.axis_names)
        manual = all_axes if axis_names is None else frozenset(axis_names)
        auto = all_axes - manual
        check = check_vma if check_vma is not None else check_rep
        if check is None:
            check = not auto  # partial-manual + check_rep is unsupported

        @functools.wraps(f)
        def traced(*args, **kwargs):
            _tls.manual_axes = _tls.manual_axes + (manual,)
            try:
                return f(*args, **kwargs)
            finally:
                _tls.manual_axes = _tls.manual_axes[:-1]

        return legacy_shard_map(
            traced,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=bool(check),
            auto=auto,
        )

    return shard_map


def _wrap_make_mesh(orig):
    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        del axis_types  # old jax: every axis behaves as Auto
        return orig(axis_shapes, axis_names, **kwargs)

    return make_mesh


def install() -> None:
    """Idempotently install every missing shim."""
    sh = jax.sharding

    if not hasattr(sh, "AxisType"):
        # NB: 0.4.x Mesh instances carry an unrelated dict-valued
        # ``axis_types``; repo code only reads per-axis types off
        # ``get_abstract_mesh()``, which is shimmed below.
        sh.AxisType = _AxisType

    if not hasattr(sh, "get_abstract_mesh"):
        sh.get_abstract_mesh = _get_abstract_mesh

    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as legacy

        jax.shard_map = _make_shard_map(legacy)

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        jax.make_mesh = _wrap_make_mesh(jax.make_mesh)

    if not hasattr(jax.lax, "axis_size"):
        from jax import core as _core

        def _axis_size(axis_name):
            if isinstance(axis_name, (tuple, list)):
                size = 1
                for name in axis_name:
                    size *= _axis_size(name)
                return size
            frame = _core.axis_frame(axis_name)
            # 0.4.x returns the bare size; keep .size compat just in case.
            return getattr(frame, "size", frame)

        jax.lax.axis_size = _axis_size
