"""Fault-tolerant training driver: restart-from-checkpoint loop.

``resilient_loop`` wraps a step function with (a) periodic async
checkpointing, (b) crash recovery — any exception classified as a
*node failure* rolls the loop back to the latest complete checkpoint
and replays (the data pipeline is counter-based, so replay is exact),
(c) a bounded restart budget. :class:`FaultInjector` drives the tests.

Chain re-forming (Torrent fault tolerance): a
:class:`SimulatedNodeFailure` that names the dead ``node`` can be
handled *without* rolling back — pass ``reform_fn`` (e.g.
``parallel.collectives.MultiChainPlan.reform``) and the loop re-forms
the Chainwrite schedule around the dead member and retries the same
step with the live state. Recovery is purely an endpoint-side re-cfg
(no NoC change), so only the failed member's sub-chain pays; the
checkpoint rollback path remains the fallback for anonymous failures
or when re-forming declines.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

log = logging.getLogger("repro.runtime")


class SimulatedNodeFailure(RuntimeError):
    """A node died mid-step. ``node`` (when known) identifies the dead
    chain member so the runtime can re-form around it instead of
    restarting from a checkpoint."""

    def __init__(self, message: str = "", node: int | None = None):
        super().__init__(message)
        self.node = node


class FaultInjector:
    """Raises SimulatedNodeFailure at the scheduled steps (once each).

    ``node`` attributes the injected failures to a specific chain
    member so the re-forming path can be driven in tests.
    """

    def __init__(self, fail_at: tuple[int, ...] = (), node: int | None = None):
        self.pending = set(fail_at)
        self.node = node

    def maybe_fail(self, step: int):
        if step in self.pending:
            self.pending.discard(step)
            raise SimulatedNodeFailure(
                f"injected failure at step {step}", node=self.node
            )


@dataclasses.dataclass
class LoopResult:
    final_step: int
    restarts: int
    metrics_history: list[dict]
    reforms: int = 0


def resilient_loop(
    *,
    state: Any,  # (params, opt_state) pytree
    step_fn: Callable[[Any, int], tuple[Any, dict]],
    num_steps: int,
    ckpt,  # CheckpointManager
    ckpt_every: int = 50,
    max_restarts: int = 10,
    start_step: int = 0,
    restore_fn: Callable[[int, Any], Any] | None = None,
    on_step: Callable[[int, dict], None] | None = None,
    reform_fn: Callable[[int], bool] | None = None,
) -> tuple[Any, LoopResult]:
    """Run ``step_fn`` for ``num_steps`` with checkpoint/restart.

    ``restore_fn(step, like_state) -> state`` defaults to
    ``ckpt.restore``; override for elastic restores.

    ``reform_fn(node) -> bool`` handles failures that name a dead chain
    member: return True to signal the Chainwrite schedule was re-formed
    around ``node`` — the loop then retries the *same* step with the
    live state (no rollback, no replay). Returning False (or an
    anonymous failure) falls back to the checkpoint-restart path.
    Re-forms and restarts share the ``max_restarts`` budget.
    """
    if restore_fn is None:
        restore_fn = lambda s, like: ckpt.restore(s, like)

    restarts = 0
    reforms = 0
    history: list[dict] = []
    step = start_step
    ckpt.save(step, state, blocking=True)  # step-0 baseline

    while step < num_steps:
        try:
            state, metrics = step_fn(state, step)
            step += 1
            history.append(metrics)
            if on_step is not None:
                on_step(step, metrics)
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except SimulatedNodeFailure as e:
            node = getattr(e, "node", None)
            if reform_fn is not None and node is not None and reform_fn(node):
                reforms += 1
                if restarts + reforms > max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                log.warning(
                    "node %d failed at step %d -> chain re-formed, retrying",
                    node, step,
                )
                continue  # state is intact: retry the same step
            restarts += 1
            if restarts + reforms > max_restarts:
                raise RuntimeError("restart budget exhausted") from e
            ckpt.wait()  # let in-flight saves land
            latest = ckpt.latest_step()
            log.warning("node failure at step %d -> restoring step %s", step, latest)
            state = restore_fn(latest, state)
            step = latest
    ckpt.save(step, state, blocking=True)
    return state, LoopResult(step, restarts, history, reforms)
