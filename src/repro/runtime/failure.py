"""Fault-tolerant training driver: restart-from-checkpoint loop.

``resilient_loop`` wraps a step function with (a) periodic async
checkpointing, (b) crash recovery — any exception classified as a
*node failure* rolls the loop back to the latest complete checkpoint
and replays (the data pipeline is counter-based, so replay is exact),
(c) a bounded restart budget. :class:`FaultInjector` drives the tests.

Chain re-forming (Torrent fault tolerance): a
:class:`SimulatedNodeFailure` that names the dead member(s) can be
handled *without* rolling back — pass ``reform_fn`` (e.g.
``parallel.collectives.MultiChainPlan.reform``) and the loop re-forms
the Chainwrite schedule around the dead members and retries the same
step with the live state. Recovery is purely an endpoint-side re-cfg
(no NoC change; the one recovery schedule is a
``core.program.plan_recovery`` ChainProgram), so only the failed
members' sub-chains pay; the checkpoint rollback path remains the
fallback for anonymous failures or when re-forming declines.

**The failure-set API.** Failures are *sets*, everywhere: a
:class:`SimulatedNodeFailure` carries ``nodes`` — a tuple of every
member that died in the event (``node`` remains as the single-failure
convenience and aliases ``nodes[0]``); ``reform_fn`` receives the
single node for a lone failure (pre-set compatibility) or the whole
tuple for a concurrent event, and every consumer down the stack
(``MultiChainPlan.reform``, ``scheduling.reform_chain``,
``chainwrite.degraded_chains``, ``simulator.chain_recovery_latency``,
``MultiChainTask.inject_failure`` accumulation) accepts one id or an
iterable via ``scheduling.normalize_failed``. Losing the *source* is
not a member failure: re-forming cannot recover it (nobody upstream
banked the payload), so ``reform_fn`` raising
:class:`SourceFailedError` (re-exported from ``core.simulator``)
makes the loop fall back to checkpoint rollback instead of retrying.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

from repro.core.simulator import SourceFailedError

log = logging.getLogger("repro.runtime")

__all__ = [
    "FaultInjector",
    "LoopResult",
    "SimulatedNodeFailure",
    "SourceFailedError",
    "resilient_loop",
]


class SimulatedNodeFailure(RuntimeError):
    """One or more nodes died mid-step. ``nodes`` (when known)
    identifies every dead chain member of the event so the runtime can
    re-form around the set instead of restarting from a checkpoint;
    ``node`` is the single-failure convenience alias (the first of
    ``nodes``)."""

    def __init__(
        self,
        message: str = "",
        node: int | None = None,
        nodes: tuple[int, ...] | None = None,
    ):
        super().__init__(message)
        if nodes is None:
            nodes = () if node is None else (int(node),)
        else:
            nodes = tuple(int(n) for n in nodes)
            if node is not None and int(node) not in nodes:
                nodes = (int(node),) + nodes
        self.nodes: tuple[int, ...] = nodes
        self.node: int | None = nodes[0] if nodes else None


class FaultInjector:
    """Raises SimulatedNodeFailure at the scheduled steps (once each).

    ``node`` / ``nodes`` attribute the injected failures to specific
    chain members so the re-forming path can be driven in tests
    (``nodes`` injects a concurrent multi-member failure event).
    """

    def __init__(
        self,
        fail_at: tuple[int, ...] = (),
        node: int | None = None,
        nodes: tuple[int, ...] | None = None,
    ):
        self.pending = set(fail_at)
        self.node = node
        self.nodes = nodes

    def maybe_fail(self, step: int):
        if step in self.pending:
            self.pending.discard(step)
            raise SimulatedNodeFailure(
                f"injected failure at step {step}",
                node=self.node,
                nodes=self.nodes,
            )


@dataclasses.dataclass
class LoopResult:
    final_step: int
    restarts: int
    metrics_history: list[dict]
    reforms: int = 0


def resilient_loop(
    *,
    state: Any,  # (params, opt_state) pytree
    step_fn: Callable[[Any, int], tuple[Any, dict]],
    num_steps: int,
    ckpt,  # CheckpointManager
    ckpt_every: int = 50,
    max_restarts: int = 10,
    start_step: int = 0,
    restore_fn: Callable[[int, Any], Any] | None = None,
    on_step: Callable[[int, dict], None] | None = None,
    reform_fn: Callable[..., bool] | None = None,
) -> tuple[Any, LoopResult]:
    """Run ``step_fn`` for ``num_steps`` with checkpoint/restart.

    ``restore_fn(step, like_state) -> state`` defaults to
    ``ckpt.restore``; override for elastic restores.

    ``reform_fn(nodes) -> bool`` handles failures that name dead chain
    members (one node id for a lone failure, the tuple for a
    concurrent event): return True to signal the Chainwrite schedule
    was re-formed around them — the loop then retries the *same* step
    with the live state (no rollback, no replay). Returning False, an
    anonymous failure, or ``reform_fn`` raising
    :class:`SourceFailedError` (the dead node was the chain *source* —
    total loss, nothing banked downstream of nothing) falls back to
    the checkpoint-restart path. Re-forms and restarts share the
    ``max_restarts`` budget.
    """
    if restore_fn is None:
        restore_fn = lambda s, like: ckpt.restore(s, like)

    restarts = 0
    reforms = 0
    history: list[dict] = []
    step = start_step
    ckpt.save(step, state, blocking=True)  # step-0 baseline

    while step < num_steps:
        try:
            state, metrics = step_fn(state, step)
            step += 1
            history.append(metrics)
            if on_step is not None:
                on_step(step, metrics)
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except SimulatedNodeFailure as e:
            nodes = getattr(e, "nodes", ()) or ()
            if not nodes and getattr(e, "node", None) is not None:
                nodes = (e.node,)  # pre-failure-set exception classes
            reformed = False
            if reform_fn is not None and nodes:
                spec = nodes[0] if len(nodes) == 1 else nodes
                try:
                    reformed = bool(reform_fn(spec))
                except SourceFailedError as total_loss:
                    log.warning(
                        "source died (%s) -> rollback, not re-form",
                        total_loss,
                    )
            if reformed:
                reforms += 1
                if restarts + reforms > max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                log.warning(
                    "node(s) %s failed at step %d -> chain re-formed, retrying",
                    list(nodes), step,
                )
                continue  # state is intact: retry the same step
            restarts += 1
            if restarts + reforms > max_restarts:
                raise RuntimeError("restart budget exhausted") from e
            ckpt.wait()  # let in-flight saves land
            latest = ckpt.latest_step()
            log.warning("node failure at step %d -> restoring step %s", step, latest)
            state = restore_fn(latest, state)
            step = latest
    ckpt.save(step, state, blocking=True)
    return state, LoopResult(step, restarts, history, reforms)
