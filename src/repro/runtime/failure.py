"""Fault-tolerant training driver: restart-from-checkpoint loop.

``resilient_loop`` wraps a step function with (a) periodic async
checkpointing, (b) crash recovery — any exception classified as a
*node failure* rolls the loop back to the latest complete checkpoint
and replays (the data pipeline is counter-based, so replay is exact),
(c) a bounded restart budget. :class:`FaultInjector` drives the tests.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

log = logging.getLogger("repro.runtime")


class SimulatedNodeFailure(RuntimeError):
    pass


class FaultInjector:
    """Raises SimulatedNodeFailure at the scheduled steps (once each)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.pending = set(fail_at)

    def maybe_fail(self, step: int):
        if step in self.pending:
            self.pending.discard(step)
            raise SimulatedNodeFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class LoopResult:
    final_step: int
    restarts: int
    metrics_history: list[dict]


def resilient_loop(
    *,
    state: Any,  # (params, opt_state) pytree
    step_fn: Callable[[Any, int], tuple[Any, dict]],
    num_steps: int,
    ckpt,  # CheckpointManager
    ckpt_every: int = 50,
    max_restarts: int = 10,
    start_step: int = 0,
    restore_fn: Callable[[int, Any], Any] | None = None,
    on_step: Callable[[int, dict], None] | None = None,
) -> tuple[Any, LoopResult]:
    """Run ``step_fn`` for ``num_steps`` with checkpoint/restart.

    ``restore_fn(step, like_state) -> state`` defaults to
    ``ckpt.restore``; override for elastic restores.
    """
    if restore_fn is None:
        restore_fn = lambda s, like: ckpt.restore(s, like)

    restarts = 0
    history: list[dict] = []
    step = start_step
    ckpt.save(step, state, blocking=True)  # step-0 baseline

    while step < num_steps:
        try:
            state, metrics = step_fn(state, step)
            step += 1
            history.append(metrics)
            if on_step is not None:
                on_step(step, metrics)
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except SimulatedNodeFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError("restart budget exhausted") from e
            ckpt.wait()  # let in-flight saves land
            latest = ckpt.latest_step()
            log.warning("node failure at step %d -> restoring step %s", step, latest)
            state = restore_fn(latest, state)
            step = latest
    ckpt.save(step, state, blocking=True)
    return state, LoopResult(step, restarts, history)
