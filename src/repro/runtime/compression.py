"""Gradient compression: int8 quantization with error feedback, and a
compressed Torrent ring all-reduce.

``quantize``/``dequantize`` implement symmetric per-tensor int8 with a
f32 scale. :class:`ErrorFeedback` keeps the quantization residual and
adds it back before the next step's compression (Seide et al. / EF-SGD),
which restores convergence despite the lossy wire format.

``compressed_chain_all_reduce`` runs the Torrent ring reduce-scatter
with int8 payloads: each hop dequantizes, accumulates in f32, and
re-quantizes for the next hop — wire bytes drop 4× vs f32 at the cost
of per-hop rounding (bounded by the per-hop scale). The final
all-gather phase also ships int8.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.chainwrite import chain_edges, _axis_size, _axis_index, _scan

PyTree = Any


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class ErrorFeedback:
    """Stateless helpers over an explicit residual pytree."""

    @staticmethod
    def init(params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    @staticmethod
    def compress(grads: PyTree, residual: PyTree):
        """Returns (pytree of (q, scale) tuples, new residual pytree)."""
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        r_leaves = treedef.flatten_up_to(residual)
        qs, res = [], []
        for g, r in zip(g_leaves, r_leaves):
            g = g.astype(jnp.float32) + r
            q, s = quantize(g)
            qs.append((q, s))
            res.append(g - dequantize(q, s))
        return (
            jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, res),
        )

    @staticmethod
    def decompress(qtree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda pair: dequantize(*pair),
            qtree,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
        )


def compressed_chain_all_reduce(
    x: jax.Array,
    axis_name,
    order=None,
) -> jax.Array:
    """Ring all-reduce with int8 wire format (call inside shard_map).

    Mean-free sum semantics identical to chain_all_reduce up to int8
    rounding; pair with :class:`ErrorFeedback` at the caller.
    """
    L = _axis_size(axis_name)
    order = tuple(range(L)) if order is None else tuple(int(o) for o in order)
    idx = _axis_index(axis_name)
    order_arr = jnp.asarray(order)
    pos = jnp.argmax(order_arr == idx)
    edges = chain_edges(order, wrap=True)

    lead = x.shape[0]
    pad = (-lead) % L
    xp = jnp.pad(x.astype(jnp.float32), [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    chunks = xp.reshape((L, xp.shape[0] // L) + x.shape[1:])

    # ---- reduce-scatter with per-hop int8 requantization -------------
    start_chunk = order_arr[(pos - 1) % L]
    acc = lax.dynamic_index_in_dim(chunks, start_chunk, 0, keepdims=False)

    def rs_step(acc, s):
        q, scale = quantize(acc)
        q = lax.ppermute(q, axis_name, edges)
        scale = lax.ppermute(scale, axis_name, edges)
        acc_in = dequantize(q, scale)
        j = order_arr[(pos - s - 1) % L]
        acc = acc_in + lax.dynamic_index_in_dim(chunks, j, 0, keepdims=False)
        return acc, None

    acc, _ = _scan(rs_step, acc, jnp.arange(1, L))

    # ---- all-gather (int8 wire) ---------------------------------------
    own_q, own_s = quantize(acc)
    out = jnp.zeros((L,) + acc.shape, jnp.float32)
    out = lax.dynamic_update_index_in_dim(out, dequantize(own_q, own_s), idx, 0)

    def ag_step(carry, s):
        q, scale, out = carry
        q = lax.ppermute(q, axis_name, edges)
        scale = lax.ppermute(scale, axis_name, edges)
        src = order_arr[(pos - s) % L]
        out = lax.dynamic_update_index_in_dim(out, dequantize(q, scale), src, 0)
        return (q, scale, out), None

    (_, _, out), _ = _scan(ag_step, (own_q, own_s, out), jnp.arange(1, L))
    full = out.reshape((L * acc.shape[0],) + x.shape[1:])
    return (full[:lead] if pad else full).astype(x.dtype)
