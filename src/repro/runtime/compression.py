"""Wire-compression numerics: symmetric int8 quantization + error
feedback.

``quantize``/``dequantize`` implement symmetric per-tensor int8 with a
f32 scale. They are the ONE definition of the lossy wire format: the
ChainProgram executor (``core.chainwrite``) applies them per hop when a
program carries ``wire_dtype="int8"`` (quantize → ship int8 frame + f32
scale → dequantize → accumulate in f32), and the numpy oracle
(``core.chainwrite_ref``) replays the identical f32 arithmetic so the
SPMD results stay bit-exact including every per-hop rounding.

Two deliberate choices make the format reproducible under compiler
rewrites (bit-exact SPMD-vs-oracle is the repo's testing contract):

* The max-abs is divided by 128 — a power of two — not 127. XLA
  rewrites division by a constant into multiplication by its rounded
  reciprocal; 1/128 is exact in f32 where 1/127 is not, so the rewrite
  (and any FMA with the ``+ 1e-12``) is value-neutral.
* The scale's mantissa is truncated to 17 significant bits before use.
  With |q| <= 127 every dequantize product ``q * scale`` then fits in
  f32's 24-bit significand EXACTLY, so a compiler that contracts the
  dequantize multiply with the downstream accumulate into an FMA
  (XLA:CPU does, and ``optimization_barrier`` does not survive to
  codegen) produces bitwise the same value as separate mul-then-add.
  The truncation costs <= 2^-17 relative scale error, noise next to
  int8's 2^-8 quantization step.

:class:`ErrorFeedback` keeps the quantization residual and adds it back
before the next step's compression (Seide et al. / EF-SGD), restoring
convergence despite the lossy wire. ``parallel.collectives`` wires it
into ``torrent_grad_reduce(error_feedback=True)``.

The hand-written ``compressed_chain_all_reduce`` that used to live here
is gone: compression is now a first-class IR dimension, so the int8
ring is simply ``plan_all_reduce(wire_dtype="int8")`` through the
ordinary executor — composing with multi-chain K, ``algo``, and the
recovery/latency pricing for free.

This module is numerics-only (no collectives), so the core executor can
import it without cycles.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any

# Keep 17 significant bits of the f32 scale (mask the low 7 explicit
# mantissa bits) so q * scale is exact in f32 — see module docstring.
_SCALE_MANTISSA_MASK = 0xFFFFFF80


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 128.0 + 1e-12
    bits = lax.bitcast_convert_type(scale.astype(jnp.float32), jnp.uint32)
    scale = lax.bitcast_convert_type(
        bits & jnp.uint32(_SCALE_MANTISSA_MASK), jnp.float32
    )
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class ErrorFeedback:
    """Stateless helpers over an explicit residual pytree."""

    @staticmethod
    def init(params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    @staticmethod
    def compress(grads: PyTree, residual: PyTree):
        """Returns (pytree of (q, scale) tuples, new residual pytree)."""
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        r_leaves = treedef.flatten_up_to(residual)
        qs, res = [], []
        for g, r in zip(g_leaves, r_leaves):
            g = g.astype(jnp.float32) + r
            q, s = quantize(g)
            qs.append((q, s))
            res.append(g - dequantize(q, s))
        return (
            jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, res),
        )

    @staticmethod
    def decompress(qtree: PyTree) -> PyTree:
        return jax.tree.map(
            lambda pair: dequantize(*pair),
            qtree,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
        )
