"""Step-time and liveness monitoring: straggler detection + heartbeats.

On a real pod, one process per host runs a :class:`Heartbeat` (a
periodically-touched file per host; the coordinator treats a stale file
as a dead host and triggers restart-from-checkpoint). In-process, the
:class:`StepMonitor` tracks per-step wall times and flags stragglers —
steps slower than ``threshold × running median`` — which is the signal
used to (a) alert, (b) exclude a host at the next elastic rescale.
"""

from __future__ import annotations

import dataclasses
import os
import statistics
import threading
import time


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float


class StepMonitor:
    def __init__(self, threshold: float = 2.5, window: int = 64):
        self.threshold = threshold
        self.window = window
        self.durations: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> StragglerEvent | None:
        assert self._t0 is not None, "end_step without start_step"
        dur = time.monotonic() - self._t0
        self._t0 = None
        hist = self.durations[-self.window :]
        self.durations.append(dur)
        if len(hist) >= 8:
            med = statistics.median(hist)
            if dur > self.threshold * med:
                ev = StragglerEvent(step, dur, med)
                self.events.append(ev)
                return ev
        return None

    def summary(self) -> dict:
        if not self.durations:
            return {"steps": 0}
        return {
            "steps": len(self.durations),
            "mean_s": statistics.fmean(self.durations),
            "median_s": statistics.median(self.durations),
            "max_s": max(self.durations),
            "stragglers": len(self.events),
        }


class Heartbeat:
    """File-touch heartbeat; ``stale_hosts`` is the coordinator view."""

    def __init__(self, dir_: str, host_id: int, interval_s: float = 1.0):
        self.dir = dir_
        self.host_id = host_id
        self.interval = interval_s
        self.path = os.path.join(dir_, f"host_{host_id}.hb")
        os.makedirs(dir_, exist_ok=True)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._beat, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def _beat(self):
        while not self._stop.is_set():
            with open(self.path, "w") as f:
                f.write(str(time.time()))
            self._stop.wait(self.interval)

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)

    @staticmethod
    def stale_hosts(dir_: str, timeout_s: float) -> list[int]:
        now = time.time()
        stale = []
        if not os.path.isdir(dir_):
            return stale
        for f in os.listdir(dir_):
            if not f.endswith(".hb"):
                continue
            host = int(f[len("host_") : -len(".hb")])
            try:
                with open(os.path.join(dir_, f)) as fh:
                    last = float(fh.read().strip() or 0)
            except (OSError, ValueError):
                last = 0.0
            if now - last > timeout_s:
                stale.append(host)
        return sorted(stale)
