"""Elastic scaling: re-factorize the mesh when hosts join/leave and
restore the (mesh-agnostic) checkpoint onto the new layout.

Policy: keep the model (TP) axis fixed when the new device count allows
(TP size is dictated by memory, not availability); absorb changes in
the data axis. When devices < tp, fall back to the largest power-of-two
TP that fits.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def choose_mesh_shape(num_devices: int, preferred_tp: int) -> tuple[int, int]:
    """(data, model) factorization for the available devices."""
    tp = min(preferred_tp, num_devices)
    while num_devices % tp:
        tp //= 2
    tp = max(tp, 1)
    return num_devices // tp, tp


def make_elastic_mesh(num_devices: int, preferred_tp: int,
                      devices=None) -> jax.sharding.Mesh:
    data, model = choose_mesh_shape(num_devices, preferred_tp)
    devs = (devices if devices is not None else jax.devices())[: data * model]
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devs).reshape(data, model), ("data", "model")
    )


def reshard_state(state, mesh: jax.sharding.Mesh, specs) -> object:
    """device_put a (restored) state pytree onto a new mesh layout."""
    shardings = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec if spec is not None else P()),
        specs,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
    return jax.tree.map(jax.device_put, state, shardings)
