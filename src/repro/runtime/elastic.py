"""Elastic scaling: re-factorize the mesh when hosts join/leave and
restore the (mesh-agnostic) checkpoint onto the new layout.

Policy: keep the model (TP) axis fixed when the new device count allows
(TP size is dictated by memory, not availability); absorb changes in
the data axis. When devices < tp, fall back to the largest power-of-two
TP that fits.

Serving-side elasticity (:func:`scale_down_plan`): replica loss does
NOT rebuild the weight-multicast plan — the highest-numbered replicas
are treated as a concurrent failure *set* and the live
``parallel.collectives.MultiChainPlan`` re-forms around them
(endpoint-side only, the same ``reform_chain`` machinery the failure
runtime uses), so in-flight schedule state and the surviving
sub-chains' orders are preserved verbatim.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def scale_down_plan(plan, old_replicas: int, new_replicas: int) -> tuple[int, ...]:
    """Shrink a replica set [0, old) to [0, new) by re-forming the live
    multicast ``plan`` (any object with ``MultiChainPlan.reform``
    semantics) around the lost replica ids — never by rebuilding it.

    Returns the lost ids ``(new, ..., old-1)``. ``new_replicas`` must
    keep at least the plan head (replica 0). Raises ``RuntimeError``
    when the plan declines (a lost id was already spliced out — the
    caller's replica accounting is stale).
    """
    old, new = int(old_replicas), int(new_replicas)
    if not 0 < new <= old:
        raise ValueError(f"cannot scale {old} replicas down to {new}")
    lost = tuple(range(new, old))
    if not lost:
        return lost
    spec = lost[0] if len(lost) == 1 else lost
    if not plan.reform(spec):
        raise RuntimeError(
            f"plan declined to re-form around lost replicas {list(lost)}"
        )
    return lost


def choose_mesh_shape(num_devices: int, preferred_tp: int) -> tuple[int, int]:
    """(data, model) factorization for the available devices."""
    tp = min(preferred_tp, num_devices)
    while num_devices % tp:
        tp //= 2
    tp = max(tp, 1)
    return num_devices // tp, tp


def make_elastic_mesh(num_devices: int, preferred_tp: int,
                      devices=None) -> jax.sharding.Mesh:
    data, model = choose_mesh_shape(num_devices, preferred_tp)
    devs = (devices if devices is not None else jax.devices())[: data * model]
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devs).reshape(data, model), ("data", "model")
    )


def reshard_state(state, mesh: jax.sharding.Mesh, specs) -> object:
    """device_put a (restored) state pytree onto a new mesh layout."""
    shardings = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec if spec is not None else P()),
        specs,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
    return jax.tree.map(jax.device_put, state, shardings)
