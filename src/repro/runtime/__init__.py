from .compression import ErrorFeedback, dequantize, quantize
from .elastic import (
    choose_mesh_shape,
    make_elastic_mesh,
    reshard_state,
    scale_down_plan,
)
from .failure import (
    FaultInjector,
    LoopResult,
    SimulatedNodeFailure,
    SourceFailedError,
    resilient_loop,
)
from .monitor import Heartbeat, StepMonitor, StragglerEvent
