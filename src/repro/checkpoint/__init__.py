from .manager import CheckpointManager
