"""Async sharded checkpointing with atomic publish and cross-mesh restore.

Layout (one directory per step)::

    <root>/ckpt_000123/
        manifest.json   — treedef (path-keyed), shapes, dtypes
        <leaf-id>.npy   — one file per pytree leaf

Design points for the 1000+-node posture:

* **Atomic publish**: writes land in ``ckpt_N.tmp``; the directory is
  ``rename``d only after fsync of the manifest — a reader never sees a
  partial checkpoint, and a crash mid-save leaves only a ``.tmp`` that
  is garbage-collected on the next save.
* **Async**: ``save`` enqueues a host-copied snapshot and returns; a
  writer thread does the I/O. ``wait()`` drains (call before exit and
  before restore-after-failure in tests).
* **Mesh-agnostic restore**: leaves are stored unsharded-logical (this
  single-host container materializes full arrays; the manifest's
  ``shard_grid`` field is where per-host shard files slot in on a real
  cluster). ``restore`` device_puts onto *any* requested shardings, so
  elastic rescale = restore with new specs.
* **keep_last_k** garbage collection.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_elem(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, root: str, keep_last_k: int = 3):
        self.root = root
        self.keep = keep_last_k
        os.makedirs(root, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._errors: list[Exception] = []
        self._thread = threading.Thread(target=self._writer, daemon=True)
        self._thread.start()

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, blocking: bool = False) -> None:
        flat = _flatten(jax.device_get(tree))  # host snapshot now
        self._q.put((step, flat))
        if blocking:
            self.wait()

    def _writer(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, flat = item
            try:
                self._write(step, flat)
            except Exception as e:  # surfaced by wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, flat: dict[str, np.ndarray]):
        name = f"ckpt_{step:09d}"
        tmp = os.path.join(self.root, name + ".tmp")
        final = os.path.join(self.root, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "shard_grid": None,  # per-host shard layout on a real cluster
            }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"ckpt_{s:09d}"), ignore_errors=True)
        for d in os.listdir(self.root):  # orphaned tmp dirs
            if d.endswith(".tmp") and not self._q.unfinished_tasks > 1:
                full = os.path.join(self.root, d)
                if os.path.isdir(full):
                    shutil.rmtree(full, ignore_errors=True)

    def wait(self):
        self._q.join()
        if self._errors:
            raise RuntimeError(f"checkpoint writer failed: {self._errors}")

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=5)

    # -- restore ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            m = re.fullmatch(r"ckpt_(\d+)", d)
            if m and os.path.exists(os.path.join(self.root, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        like: PyTree,
        *,
        shardings: PyTree | None = None,
    ) -> PyTree:
        """Restore into the structure of ``like`` (values ignored).
        ``shardings``: optional matching pytree of Shardings — this is
        the elastic-rescale path (same bytes, new mesh layout)."""
        cdir = os.path.join(self.root, f"ckpt_{step:09d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        out = []
        for i, (path, leaf) in enumerate(paths):
            key = _SEP.join(_path_elem(p) for p in path)
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint {step} missing leaf {key}")
            arr = np.load(os.path.join(cdir, meta["file"]))
            expect = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != expect:
                raise ValueError(f"{key}: ckpt shape {arr.shape} != {expect}")
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, [x for x in out])
